"""Per-iteration MCMC cost: PR-1 gather-delta engine vs the bitmask-cached
engine (ISSUE 3 tentpole gate: >= 2x at n = 64, window = 8, dense path),
plus the SHARDED pair (ISSUE 4 gate: the mesh-native bitmask delta path
>= 2x the per-shard mask-recompute path at n = 64, window = 8 on a simulated
4-device mesh — `--sharded`).

Both engines run the REAL sampler (mcmc_run / sharded_chain_step, identical
keys hence identical proposals) over the same synthetic dense tables at
n ∈ {16, 37, 64} — n = 37 is the paper's CPU/GPU crossover point, n = 64 its
headline "n > 60" scale. The PR-1 baseline recomputes each window node's
consistency mask from (blk, s) position gathers every proposal
(core/order_scoring.score_order_delta); the bitmask engine patches cached
packed violation planes with word ops (score_order_delta_bitmask). The two
paths are asserted BITWISE-equal on a shared prefix before anything is
timed.

  PYTHONPATH=src python benchmarks/mcmc_bench.py [--smoke] [--iters N] [--s K]
  PYTHONPATH=src python benchmarks/mcmc_bench.py --sharded [--smoke]

Emits experiments/bench/BENCH_mcmc[_sharded].json (per-iteration wall ms per
engine), mirrored to the repo root as BENCH_mcmc[_sharded].json.
"""
from __future__ import annotations

import argparse
import functools
import os
import sys

# --sharded simulates a small device mesh on the host platform; the flag must
# land before the FIRST jax import (jax locks the device count at init)
if "--sharded" in sys.argv and "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=4").strip()

import jax
import jax.numpy as jnp
import numpy as np

try:
    from .common import emit, timeit
except ImportError:                      # run as a plain script
    import os
    import sys
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from common import emit, timeit

from repro.core.combinatorics import build_pst, n_parent_sets
from repro.core.mcmc import BitmaskDelta, mcmc_run
from repro.core.order_scoring import (NEG_INF, build_membership_planes,
                                      build_violation_planes, delta_window,
                                      score_order_blocked, score_order_delta,
                                      score_order_delta_bitmask)

WINDOW = 8
GATE_N = 64
GATE_SPEEDUP = 2.0


def make_problem(n: int, s: int, block: int, seed: int = 0):
    S = n_parent_sets(n - 1, s)
    pst, _ = build_pst(n - 1, s)
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.normal(-40, 8, (n, S)).astype(np.float32))
    pad = (-S) % block
    table = jnp.pad(table, ((0, 0), (0, pad)), constant_values=NEG_INF)
    pst = jnp.pad(jnp.asarray(pst), ((0, pad), (0, 0)), constant_values=-1)
    return table, pst, S


def bench_size(n: int, s: int, iters: int, block: int = 4096) -> dict:
    table, pst, S = make_problem(n, s, block)
    block = min(block, table.shape[1])
    w = delta_window(n, WINDOW)
    assert w, f"n={n} too small for window {WINDOW}"
    score_fn = functools.partial(score_order_blocked, table, pst, block=block)

    def delta_fn(pos, lo, prev_ls, prev_idx):
        return score_order_delta(table, pst, pos, prev_ls, prev_idx, lo,
                                 window=w, block=block)

    cm = build_membership_planes(pst, n)
    planes_fn = functools.partial(build_violation_planes, pst)

    def bitmask_fn(pos, lo, prev_ls, prev_idx, pos_old, planes):
        return score_order_delta_bitmask(table, cm, pos, prev_ls, prev_idx,
                                         lo, pos_old, planes, window=w,
                                         block=block)
    bitmask = BitmaskDelta(bitmask_fn)

    def run_pr1():
        st, _ = mcmc_run(jax.random.key(0), n, score_fn, iters,
                         delta_fn=delta_fn, window=w)
        return st.score

    def run_bitmask():
        st, _ = mcmc_run(jax.random.key(0), n, score_fn, iters,
                         delta_fn=bitmask, window=w, planes_fn=planes_fn)
        return st.score

    # same key + same proposals: the engines must agree bitwise before we
    # time them (never time a bug)
    a, _ = mcmc_run(jax.random.key(1), n, score_fn, min(iters, 50),
                    delta_fn=delta_fn, window=w)
    b, _ = mcmc_run(jax.random.key(1), n, score_fn, min(iters, 50),
                    delta_fn=bitmask, window=w, planes_fn=planes_fn)
    assert float(a.score) == float(b.score), "bitmask != gather delta"
    np.testing.assert_array_equal(np.asarray(a.pos), np.asarray(b.pos))
    np.testing.assert_array_equal(np.asarray(a.cur_ls), np.asarray(b.cur_ls))

    t_pr1 = timeit(run_pr1)
    t_bit = timeit(run_bitmask)
    return {
        "n": n, "S": S, "window": w, "iters": iters,
        "pr1_delta_ms_per_it": t_pr1 / iters * 1e3,
        "bitmask_ms_per_it": t_bit / iters * 1e3,
        "speedup": t_pr1 / t_bit,
    }


def bench_sharded(n: int, s: int, iters: int, block: int = 1024) -> dict:
    """Sharded pair on the simulated mesh: sharded_chain_step with the
    S-sharded cached planes (cm passed) vs the per-shard mask-RECOMPUTE
    delta path (no cm) — identical keys, identical proposals, asserted
    bitwise-equal on a shared prefix before timing. Chains ride a trivial
    data axis; the table, membership planes and violation planes are TP over
    'model'; per iteration only the (w,) pmax/pmin pair crosses the mesh."""
    from repro.core.mcmc import init_chain
    from repro.core.order_scoring import build_membership_planes
    from repro.core.sharded_scoring import (_shard_block,
                                            make_sharded_planes_fn,
                                            pad_table, score_order_sharded,
                                            sharded_chain_step)
    from repro.runtime.jax_compat import make_auto_mesh, mesh_context

    tp = jax.device_count()
    mesh = make_auto_mesh((1, tp), ("data", "model"))
    S = n_parent_sets(n - 1, s)
    pst_np, _ = build_pst(n - 1, s)
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(-40, 8, (n, S)).astype(np.float32))
    blk = _shard_block(S, tp, block)
    table, pst = pad_table(table, jnp.asarray(pst_np), tp * blk)
    w = delta_window(n, WINDOW)
    assert w, f"n={n} too small for window {WINDOW}"
    cm = build_membership_planes(pst, n)
    planes_fn = make_sharded_planes_fn(pst, mesh, stacked=True)

    def score_fn(pos):
        return score_order_sharded(table, pst, pos, mesh, block=blk)

    @functools.partial(jax.jit, static_argnames=("length", "mask"))
    def run(states, *, length, mask):
        def body(st, _):
            return sharded_chain_step(st, table, pst, mesh,
                                      cm if mask else None, block=blk,
                                      window=w), None
        states, _ = jax.lax.scan(body, states, None, length=length)
        return states

    with mesh_context(mesh):
        states = jax.vmap(lambda k: init_chain(k, n, score_fn))(
            jax.random.split(jax.random.key(0), 1))
        sm = states._replace(mask_planes=planes_fn(states.pos))

        # same key + same proposals: the engines must agree bitwise before
        # we time them (never time a bug)
        a = run(states, length=min(iters, 30), mask=False)
        b = run(sm, length=min(iters, 30), mask=True)
        np.testing.assert_array_equal(np.asarray(a.pos), np.asarray(b.pos))
        np.testing.assert_array_equal(np.asarray(a.cur_ls),
                                      np.asarray(b.cur_ls))
        assert (np.asarray(b.cur_idx) < S).all(), \
            "padded rank leaked into best_idx"

        t_rec = timeit(lambda: run(states, length=iters, mask=False).score)
        t_bit = timeit(lambda: run(sm, length=iters, mask=True).score)
    return {
        "n": n, "S": S, "window": w, "iters": iters, "devices": tp,
        "recompute_ms_per_it": t_rec / iters * 1e3,
        "bitmask_ms_per_it": t_bit / iters * 1e3,
        "speedup": t_rec / t_bit,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes/iters — CI wiring check, seconds")
    ap.add_argument("--iters", type=int, default=0,
                    help="override iterations per timed run")
    ap.add_argument("--s", type=int, default=3, help="max parent-set size")
    ap.add_argument("--sharded", action="store_true",
                    help="benchmark the sharded pair on a simulated "
                         "4-device mesh (mask recompute vs cached planes)")
    args = ap.parse_args(argv)

    if args.smoke:
        sizes, iters = [16], args.iters or 30
    else:
        sizes, iters = [16, 37, 64], args.iters or 300

    if args.sharded:
        iters = args.iters or (30 if args.smoke else 200)
        rows = [bench_sharded(n, args.s, iters) for n in sizes]
        emit("BENCH_mcmc_sharded", rows)
        if not args.smoke:
            last = rows[-1]
            print(f"\nn={last['n']}: sharded bitmask delta path is "
                  f"{last['speedup']:.2f}x the per-shard mask-recompute path "
                  f"on {last['devices']} devices "
                  f"(gate >= {GATE_SPEEDUP:g}x at n={GATE_N})")
            if last["n"] == GATE_N and last["speedup"] < GATE_SPEEDUP:
                raise SystemExit(
                    f"FAIL: {last['speedup']:.2f}x < {GATE_SPEEDUP:g}x gate")
        return rows

    rows = [bench_size(n, args.s, iters) for n in sizes]
    emit("BENCH_mcmc", rows)
    if not args.smoke:
        last = rows[-1]
        print(f"\nn={last['n']}: bitmask-cached engine is "
              f"{last['speedup']:.2f}x the PR-1 gather-delta engine "
              f"(gate >= {GATE_SPEEDUP:g}x at n={GATE_N})")
        if last["n"] == GATE_N and last["speedup"] < GATE_SPEEDUP:
            raise SystemExit(
                f"FAIL: {last['speedup']:.2f}x < {GATE_SPEEDUP:g}x gate")
    return rows


if __name__ == "__main__":
    main()
