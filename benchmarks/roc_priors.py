"""Paper Figures 9 & 10: ROC points for a 20-node graph (1,000 samples) with
progressively stronger pairwise priors, at 1,000 and 10,000 MCMC iterations.

Point construction follows §VI exactly: learn once with no prior; identify
mistakenly-removed / mistakenly-added edges; assign interface value hi/lo to
a random fraction of those mistakes; relearn.
"""
from __future__ import annotations

import numpy as np

from repro.core import random_cpts, random_dag, roc_point
from repro.data.bn_sampler import ancestral_sample
from repro.launch.bn_learn import LearnConfig, learn_structure

from .common import emit

# (interface hi for missing edges, lo for spurious edges, fraction of mistakes)
POINTS = [
    ("no-prior", None, None, 0.0),
    ("R=0.7/0.2 @20%", 0.7, 0.2, 0.2),
    ("R=0.7/0.2 @40%", 0.7, 0.2, 0.4),
    ("R=0.8/0.1 @20%", 0.8, 0.1, 0.2),
    ("R=0.8/0.1 @40%", 0.8, 0.1, 0.4),
]


def _prior_from_mistakes(rng, learned, truth, hi, lo, frac):
    n = truth.shape[0]
    R = np.full((n, n), 0.5, np.float32)
    missing = (truth == 1) & (learned == 0)       # mistakenly removed
    spurious = (learned == 1) & (truth == 0)      # mistakenly added
    for (m, i) in zip(*np.nonzero(missing)):
        if rng.random() < frac:
            R[i, m] = hi                          # R[i,m]: edge m -> i
    for (m, i) in zip(*np.nonzero(spurious)):
        if rng.random() < frac:
            R[i, m] = lo
    return R


def run(iters_list=(1000, 10000), n: int = 20, m: int = 1000,
        q: int = 2, chains: int = 2) -> list[dict]:
    rng = np.random.default_rng(3)
    truth = random_dag(rng, n, max_parents=4)
    data = ancestral_sample(rng, truth, random_cpts(rng, truth, q), m, q)
    rows = []
    for iters in iters_list:
        cfg = LearnConfig(q=q, s=4, iters=iters, seed=1, chains=chains)
        base = learn_structure(data, cfg)
        base_adj = base["adjacency"]
        for label, hi, lo, frac in POINTS:
            if hi is None:
                adj = base_adj
            else:
                R = _prior_from_mistakes(np.random.default_rng(5), base_adj,
                                         truth, hi, lo, frac)
                adj = learn_structure(data, cfg, prior_matrix=R)["adjacency"]
            fp, tp = roc_point(adj, truth)
            rows.append({"iters": iters, "prior": label,
                         "tp_rate": tp, "fp_rate": fp})
    emit("roc_priors", rows)
    return rows


if __name__ == "__main__":
    run()
