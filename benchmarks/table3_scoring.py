"""Paper Table III: order-scoring runtime per MCMC iteration vs graph size.

The paper compares serial GPP vs its GPU kernel (peak 10.8× at n=35-50). On
this CPU-only container we measure:

  * jnp chunked path   — the production CPU/oracle path (XLA-vectorized);
  * naive per-set loop — a GPP-like serial python/numpy baseline (small n);
  * Pallas kernel      — interpret mode (correctness proxy; its TPU-expected
    time is derived from the roofline model instead of wall clock).

Scoring cost depends only on (n, S): tables are synthetic random — exactly
the paper's setting of per-iteration scoring time.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.combinatorics import build_pst, n_parent_sets
from repro.core.order_scoring import consistent_mask, score_order_chunked
from repro.launch.roofline import HW

from .common import emit, timeit

NAIVE_CAP = 25      # serial baseline gets slow fast, like the paper's GPP
PALLAS_CAP = 30     # interpret mode is a python loop over blocks


def naive_score(table: np.ndarray, pst: np.ndarray, pos: np.ndarray) -> float:
    """GPP-like serial scoring (paper's CPU baseline: loop over parent sets)."""
    n, S = table.shape
    total = 0.0
    for i in range(n):
        pnode = pst + (pst >= i)
        ppos = pos[np.clip(pnode, 0, n - 1)]
        ok = np.where(pst < 0, True, ppos < pos[i]).all(axis=1)
        total += table[i, ok].max()
    return total


def tpu_expected_s(n: int, S: int) -> float:
    """Roofline-derived per-iteration kernel time on one v5e chip: the kernel
    streams the (n, S) f32 table + (S, s) i32 PST once from HBM; compute is
    a masked max (VPU) — memory-bound."""
    bytes_moved = n * S * 4 + S * 4 * 4
    return bytes_moved / HW["hbm_bw"]


def run(ns=(13, 15, 17, 20, 25, 30, 35, 40, 50, 60), s: int = 4,
        use_pallas: bool = True) -> list[dict]:
    rows = []
    rng = np.random.default_rng(0)
    for n in ns:
        S = n_parent_sets(n - 1, s)
        pst_np, _ = build_pst(n - 1, s)
        table_np = rng.normal(-50, 10, (n, S)).astype(np.float32)
        pos_np = rng.permutation(n).astype(np.int32)
        table, pst = jnp.asarray(table_np), jnp.asarray(pst_np)
        pos = jnp.asarray(pos_np)

        block = min(4096, S)
        pad = (-S) % block
        tbl_p = jnp.pad(table, ((0, 0), (0, pad)), constant_values=-3e38)
        pst_p = jnp.pad(pst, ((0, pad), (0, 0)), constant_values=-1)
        t_jnp = timeit(lambda: score_order_chunked(tbl_p, pst_p, pos,
                                                   block=block))

        t_naive = None
        if n <= NAIVE_CAP:
            t0 = time.perf_counter()
            naive_score(table_np, pst_np, pos_np)
            t_naive = time.perf_counter() - t0

        t_pal = None
        if use_pallas and n <= PALLAS_CAP:
            from repro.kernels.order_score import order_score
            t_pal = timeit(lambda: order_score(table, pst, pos,
                                               block_s=min(2048, S + (-S) % 8),
                                               interpret=True), reps=1)

        rows.append({
            "n_nodes": n, "S": S,
            "t_serial_s": t_naive if t_naive is not None else "-",
            "t_jnp_s": t_jnp,
            "t_pallas_interp_s": t_pal if t_pal is not None else "-",
            "tpu_expected_s": tpu_expected_s(n, S),
            "speedup_jnp_vs_serial":
                (t_naive / t_jnp) if t_naive else "-",
        })
    emit("table3_scoring", rows)
    return rows


if __name__ == "__main__":
    run()
