"""Roofline table (deliverable g): per (arch × shape × mesh) the three terms
derived from the compiled dry-run artifacts in experiments/dryrun/.

Run ``python -m repro.launch.dryrun --all`` first; this benchmark only
aggregates and prints (it never compiles)."""
from __future__ import annotations

import glob
import json
import os

from .common import emit

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def run() -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        d = json.load(open(path))
        if not d.get("ok"):
            rows.append({"mode": "roofline",
                         "variant": f"{d.get('arch')}/{d.get('shape')}"
                                    f"/{d.get('mesh')}",
                         "arch": d.get("arch"), "shape": d.get("shape"),
                         "mesh": d.get("mesh"), "ERROR": d.get("error")})
            continue
        dom = {"compute": d["t_compute"], "memory": d["t_memory"],
               "collective": d["t_collective"]}[d["bottleneck"]]
        total = max(d["t_compute"], d["t_memory"], d["t_collective"])
        rows.append({
            "mode": "roofline",
            "variant": f"{d['arch']}/{d['shape']}/{d['mesh']}",
            "arch": d["arch"], "shape": d["shape"], "mesh": d["mesh"],
            "t_compute_s": d["t_compute"], "t_memory_s": d["t_memory"],
            "t_collective_s": d["t_collective"],
            "bottleneck": d["bottleneck"],
            "roofline_frac": d["t_compute"] / total if total else 0.0,
            "useful_flops_ratio": d["useful_ratio"],
            "peak_GiB_per_dev": d["peak_memory_bytes"] / 2**30,
        })
    emit("roofline_report", rows)
    return rows


if __name__ == "__main__":
    run()
