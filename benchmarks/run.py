"""Benchmark harness — one module per paper table/figure.

  table2_parent_sets   Table II  — all vs size-limited parent-set generation
  table3_scoring       Table III — per-iteration order-scoring time vs n
  table45_end2end      Tables IV/V — end-to-end STN/ALARM, all-vs-limited
  roc_priors           Figs 9/10 — ROC with pairwise priors, 1k/10k iters
  fault_injection      Fig 11  — noise-tolerance ROC sweep
  kernel_scoring       Table III (GPU cols) — Pallas kernels vs oracle
  roofline_report      §Roofline — aggregates experiments/dryrun/*.json

``python -m benchmarks.run`` runs the quick profile (CPU-minutes);
``--full`` uses the paper's iteration counts; ``--only <name>`` selects one.
Results land in experiments/bench/*.json and are printed as tables.
"""
from __future__ import annotations

import argparse
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale iteration counts (slow on CPU)")
    args = ap.parse_args(argv)

    from . import (baseline_sum, fault_injection, kernel_scoring, roc_priors,
                   roofline_report, table2_parent_sets, table3_scoring,
                   table45_end2end)

    quick = not args.full
    suites = {
        "table2_parent_sets": lambda: table2_parent_sets.run(),
        "table3_scoring": lambda: table3_scoring.run(
            ns=(13, 15, 17, 20, 25, 30, 35, 40, 50, 60)),
        "table45_end2end": lambda: table45_end2end.run(
            iters=500 if quick else 10000),
        "roc_priors": lambda: roc_priors.run(
            iters_list=(2000,) if quick else (1000, 10000), chains=4),
        "fault_injection": lambda: fault_injection.run(
            iters=2000 if quick else 10000, chains=2),
        "baseline_sum": lambda: baseline_sum.run(
            iters=1000 if quick else 10000),
        "kernel_scoring": lambda: kernel_scoring.run(),
        "roofline_report": lambda: roofline_report.run(),
    }
    todo = [args.only] if args.only else list(suites)
    t_all = time.time()
    for name in todo:
        t0 = time.time()
        suites[name]()
        print(f"[{name}] {time.time() - t0:.1f}s")
    print(f"\nall benchmarks done in {time.time() - t_all:.1f}s")


if __name__ == "__main__":
    main()
