"""Delta vs full per-iteration MCMC throughput (ISSUE 1 tentpole).

The paper's per-iteration cost is the order rescore: O(n·S) for the full
blocked path. A bounded-window move only perturbs `w` positions, so the
incremental path (core/order_scoring.score_order_delta) does O(w·S) — an
n/w asymptotic win that GROWS with graph size. This harness runs the real
sampler (mcmc_run, identical proposals, window=8) with both scoring paths
at n ∈ {16, 32, 64} and reports iterations/sec and the speedup.

  PYTHONPATH=src python benchmarks/delta_vs_full.py [--smoke] [--iters N]

Scoring cost depends only on (n, S): tables are synthetic random, exactly
the setting of benchmarks/table3_scoring.py.
"""
from __future__ import annotations

import argparse
import functools

import jax
import jax.numpy as jnp
import numpy as np

try:
    from .common import emit, timeit
except ImportError:                      # run as a plain script
    import os
    import sys
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from common import emit, timeit

from repro.core.combinatorics import build_pst, n_parent_sets
from repro.core.mcmc import mcmc_run
from repro.core.order_scoring import (NEG_INF, delta_window,
                                      score_order_blocked, score_order_delta)

WINDOW = 8


def make_problem(n: int, s: int, block: int, seed: int = 0):
    S = n_parent_sets(n - 1, s)
    pst, _ = build_pst(n - 1, s)
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.normal(-40, 8, (n, S)).astype(np.float32))
    pad = (-S) % block
    table = jnp.pad(table, ((0, 0), (0, pad)), constant_values=NEG_INF)
    pst = jnp.pad(jnp.asarray(pst), ((0, pad), (0, 0)), constant_values=-1)
    return table, pst, S


def bench_size(n: int, s: int, iters: int, block: int = 4096) -> dict:
    table, pst, S = make_problem(n, s, block)
    block = min(block, table.shape[1])
    w = delta_window(n, WINDOW)
    assert w, f"n={n} too small for window {WINDOW}"
    score_fn = functools.partial(score_order_blocked, table, pst, block=block)

    def delta_fn(pos, lo, prev_ls, prev_idx):
        return score_order_delta(table, pst, pos, prev_ls, prev_idx, lo,
                                 window=w, block=block)

    def run_full():
        st, _ = mcmc_run(jax.random.key(0), n, score_fn, iters, window=w)
        return st.score

    def run_delta():
        st, _ = mcmc_run(jax.random.key(0), n, score_fn, iters,
                         delta_fn=delta_fn, window=w)
        return st.score

    # same key + same proposals: the two paths must agree before we time them
    a, _ = mcmc_run(jax.random.key(1), n, score_fn, min(iters, 50), window=w)
    b, _ = mcmc_run(jax.random.key(1), n, score_fn, min(iters, 50),
                    delta_fn=delta_fn, window=w)
    assert float(a.score) == float(b.score), "delta != full — do not time a bug"

    t_full = timeit(run_full)
    t_delta = timeit(run_delta)
    return {
        "n": n, "S": S, "window": w, "iters": iters,
        "full_its_per_s": iters / t_full,
        "delta_its_per_s": iters / t_delta,
        "speedup": t_full / t_delta,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes/iters — CI wiring check, seconds")
    ap.add_argument("--iters", type=int, default=0,
                    help="override iterations per timed run")
    ap.add_argument("--s", type=int, default=3, help="max parent-set size")
    args = ap.parse_args(argv)

    if args.smoke:
        sizes, iters = [16], args.iters or 30
    else:
        sizes, iters = [16, 32, 64], args.iters or 300
    rows = [bench_size(n, args.s, iters) for n in sizes]
    emit("delta_vs_full", rows)
    if not args.smoke:
        last = rows[-1]
        print(f"\nn={last['n']}: delta path is {last['speedup']:.1f}x the "
              f"full-rescore path (target >= 3x)")
    return rows


if __name__ == "__main__":
    main()
