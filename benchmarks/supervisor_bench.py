"""Run-supervisor overhead: the segmented bitmask engine driven by the bare
host loop vs runtime/supervisor.RunSupervisor (ISSUE 8 gate: supervision
costs <= 5% iters/sec at n = 64).

Both drivers call the SAME jitted segment runner with the same keys and the
same segment boundaries — the supervisor only adds host work per boundary
(health guards over (C,) arrays, fault-plan lookups) — and supervision with
no faults must be a pure OBSERVER: the final chain states are asserted
bitwise-equal before anything is timed.

  PYTHONPATH=src python benchmarks/supervisor_bench.py [--smoke] [--iters N]

Rows land in BENCH_mcmc.json (mode="supervised") beside the engine and
telemetry rows, mirrored to the repo root.
"""
from __future__ import annotations

import argparse
import functools

import jax
import jax.numpy as jnp
import numpy as np

try:
    from .common import emit, timeit
except ImportError:                      # run as a plain script
    import os
    import sys
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from common import emit, timeit

from repro.core.mcmc import (BitmaskDelta, init_chain,
                             make_traced_segment_runner, mcmc_step)
from repro.core.order_scoring import (build_membership_planes,
                                      build_violation_planes, delta_window,
                                      score_order_blocked,
                                      score_order_delta_bitmask)
from repro.runtime.supervisor import RunSupervisor

from mcmc_bench import make_problem

WINDOW = 8
CHAINS = 4
SEGMENTS = 8                    # boundaries per timed run
GATE_N = 64
GATE_OVERHEAD = 0.05            # supervision may cost at most 5% iters/sec


def bench_size(n: int, s: int, iters: int, block: int = 4096) -> dict:
    table, pst, S = make_problem(n, s, block)
    block = min(block, table.shape[1])
    w = delta_window(n, WINDOW)
    assert w, f"n={n} too small for window {WINDOW}"
    score_fn = functools.partial(score_order_blocked, table, pst, block=block)
    cm = build_membership_planes(pst, n)
    planes_fn = functools.partial(build_violation_planes, pst)

    def bitmask_fn(pos, lo, prev_ls, prev_idx, pos_old, planes):
        return score_order_delta_bitmask(table, cm, pos, prev_ls, prev_idx,
                                         lo, pos_old, planes, window=w,
                                         block=block)
    step = lambda st: mcmc_step(st, score_fn, BitmaskDelta(bitmask_fn), w)
    run_segment = make_traced_segment_runner(step)
    seg = max(iters // SEGMENTS, 1)

    def states0():
        keys = jax.random.split(jax.random.key(0), CHAINS)
        return jax.vmap(
            lambda k: init_chain(k, n, score_fn, planes_fn=planes_fn))(keys)

    def bare(states):
        done = 0
        while done < iters:
            length = min(seg, iters - done)
            states, _ = run_segment(states, None, jnp.int32(done),
                                    length=length)
            done += length
        return states

    def supervised(states):
        sup = RunSupervisor(iters=iters, seg=seg, chains=CHAINS, heal=True,
                            planes_fn=jax.vmap(planes_fn))
        return sup.run(run_segment, states, None).states

    # supervision with no faults must observe, never steer: same keys, same
    # boundaries, final chain states bitwise-equal (never time a bug)
    a, b = bare(states0()), supervised(states0())
    np.testing.assert_array_equal(np.asarray(a.pos), np.asarray(b.pos))
    np.testing.assert_array_equal(np.asarray(a.score), np.asarray(b.score))
    np.testing.assert_array_equal(np.asarray(a.accepts),
                                  np.asarray(b.accepts))

    t_bare = timeit(lambda: bare(states0()).score, reps=5)
    t_sup = timeit(lambda: supervised(states0()).score, reps=5)
    return {
        "n": n, "S": S, "window": w, "iters": iters, "chains": CHAINS,
        "mode": "supervised", "segments": SEGMENTS,
        "bare_ms_per_it": t_bare / iters * 1e3,
        "supervised_ms_per_it": t_sup / iters * 1e3,
        "overhead": t_sup / t_bare - 1.0,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes/iters — CI wiring check, seconds")
    ap.add_argument("--iters", type=int, default=0,
                    help="override iterations per timed run")
    ap.add_argument("--s", type=int, default=3, help="max parent-set size")
    args = ap.parse_args(argv)

    if args.smoke:
        sizes, iters = [16], args.iters or 64
    else:
        sizes, iters = [16, 64], args.iters or 400

    rows = [bench_size(n, args.s, iters) for n in sizes]
    emit("BENCH_mcmc", rows)
    if not args.smoke:
        last = rows[-1]
        print(f"\nn={last['n']}: run supervision costs "
              f"{last['overhead'] * 100:.1f}% iters/sec "
              f"(gate <= {GATE_OVERHEAD * 100:g}% at n={GATE_N})")
        if last["n"] == GATE_N and last["overhead"] > GATE_OVERHEAD:
            raise SystemExit(
                f"FAIL: {last['overhead'] * 100:.1f}% > "
                f"{GATE_OVERHEAD * 100:g}% overhead gate")
    return rows


if __name__ == "__main__":
    main()
