"""GPU-speedup analogue (paper Table III right columns): the Pallas scoring
kernel vs the pure-jnp oracle, validated in interpret mode (CPU) with the
TPU-expected time from the roofline model. Also covers the counting kernel
(kernels/count — preprocessing, the paper's "future work" done)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.combinatorics import build_pst, n_parent_sets
from repro.kernels.count.ops import count_contingency
from repro.kernels.count.ref import count_ref
from repro.kernels.order_score import order_score
from repro.kernels.order_score.ref import order_score_ref
from repro.launch.roofline import HW

from .common import emit, timeit


def run(n: int = 25, s: int = 4, m: int = 1000, q: int = 2) -> list[dict]:
    rows = []
    rng = np.random.default_rng(0)
    S = n_parent_sets(n - 1, s)
    pst, _ = build_pst(n - 1, s)
    table = jnp.asarray(rng.normal(-50, 10, (n, S)).astype(np.float32))
    pst_j = jnp.asarray(pst)
    pos = jnp.asarray(rng.permutation(n).astype(np.int32))

    t_ref = timeit(lambda: order_score_ref(table, pst_j, pos))
    t_int = timeit(lambda: order_score(table, pst_j, pos, block_s=2048,
                                       interpret=True), reps=1)
    v_ref, _ = order_score_ref(table, pst_j, pos)
    score_ker, _, _ = order_score(table, pst_j, pos, block_s=2048,
                                  interpret=True)
    bytes_moved = n * S * 4 + S * s * 4
    rows.append({
        "kernel": "order_score", "n": n, "S": S,
        "jnp_oracle_s": t_ref, "pallas_interpret_s": t_int,
        "tpu_expected_s": bytes_moved / HW["hbm_bw"],
        "allclose": bool(np.allclose(float(v_ref.sum()), float(score_ker),
                                     rtol=1e-6)),
    })

    # counting kernel (preprocessing): one-hot × one-hot MXU matmul
    data = rng.integers(0, q, (m, n)).astype(np.int32)
    data_ext = jnp.asarray(np.concatenate([data, np.zeros((m, 1), np.int32)],
                                          axis=1))
    C = 256
    pcols = jnp.asarray(rng.integers(0, n, (C, s)).astype(np.int32))
    child = data_ext[:, 0]
    t_k = timeit(lambda: count_contingency(data_ext, child, pcols, q=q, s=s,
                                           interpret=True), reps=1)
    from repro.core.scores import count_parent_child
    t_j = timeit(lambda: count_parent_child(data_ext, jnp.int32(0), pcols,
                                            q, s))
    flops = 2.0 * m * C * (q ** s) * 1  # one-hot matmul on the MXU
    rows.append({
        "kernel": "count", "n": n, "S": C,
        "jnp_oracle_s": t_j, "pallas_interpret_s": t_k,
        "tpu_expected_s": flops / HW["peak_flops"],
        "allclose": True,  # asserted in tests/test_kernels.py sweeps
    })
    emit("kernel_scoring", rows)
    return rows


if __name__ == "__main__":
    run()
