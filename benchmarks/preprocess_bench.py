"""Dense loop vs fused preprocessing pipeline (ISSUE 2 tentpole).

Preprocessing is the end-to-end bottleneck at n >= 37 now that MCMC
iterations are O(window*S) (PR 1). This harness times full score-table
construction both ways on identical data:

* dense:  core/scores.build_score_table — the oracle host loop (per-node
  batched chunk launches, per-node one-hot rebuilds);
* fused:  preprocess.build_score_table_fused — count each column subset once
  against all n children, LUT-score in the same pass, rank-gather assembly.

and reports the speedup plus the max absolute score deviation (gate: >= 3x
at n = 64 and <= 1e-4 error; the fused path is bitwise-equal on CPU).

``--stream`` benches the streaming-pruned assembly instead (ISSUE 6
tentpole): dense fused-build-then-prune vs preprocess/streaming.py going
straight into the SparseScoreTable, reporting wall clocks, the streaming
path's self-measured peak assembly bytes vs the dense (n, S) table bytes,
and process peak RSS. Equality of the two pruned tables is asserted before
anything is timed. Rows carry mode="stream" so the merge-by-config writer
files them beside — never over — the dense-vs-fused rows.

  PYTHONPATH=src python benchmarks/preprocess_bench.py \
      [--smoke] [--stream] [--samples M]

Emits experiments/bench/BENCH_preprocess.json (merged by row config).
"""
from __future__ import annotations

import argparse
import resource

import numpy as np

try:
    from .common import emit, timeit
except ImportError:                      # run as a plain script
    import os
    import sys
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from common import emit, timeit

from repro.core.combinatorics import n_parent_sets
from repro.core.scores import build_score_table
from repro.preprocess import build_score_table_fused

# (n, q, s): s shrinks as n grows to keep the dense baseline's wall clock
# tractable on CPU — the fused/dense ratio only grows with S.
SIZES = [(16, 2, 3), (37, 2, 3), (64, 2, 2)]
SMOKE_SIZES = [(16, 2, 2)]
# --stream sizes: big enough that the dense (n, S) intermediate dominates
# (n = 64, s = 4 -> S ~ 637k, dense table ~163 MB with its rank map).
STREAM_SIZES = [(64, 2, 3), (64, 2, 4)]
STREAM_SMOKE_SIZES = [(16, 2, 3)]
STREAM_DELTA = 20.0


def bench_size(n: int, q: int, s: int, m: int) -> dict:
    rng = np.random.default_rng(n)
    data = rng.integers(0, q, size=(m, n)).astype(np.int32)

    def run_dense():
        return build_score_table(data, q=q, s=s).table

    def run_fused():
        return build_score_table_fused(data, q=q, s=s).table

    # correctness first — never time a wrong result
    err = float(np.abs(np.asarray(run_fused()) - np.asarray(run_dense())).max())
    assert err <= 1e-4, f"fused deviates from oracle by {err}"

    t_dense = timeit(run_dense)
    t_fused = timeit(run_fused)
    return {
        "n": n, "q": q, "s": s, "m": m, "S": n_parent_sets(n - 1, s),
        "dense_s": t_dense,
        "fused_s": t_fused,
        "speedup": t_dense / t_fused,
        "max_abs_err": err,
    }


def bench_stream(n: int, q: int, s: int, m: int, delta: float) -> dict:
    rng = np.random.default_rng(n)
    data = rng.integers(0, q, size=(m, n)).astype(np.int32)

    def run_dense_prune():
        return build_score_table_fused(data, q=q, s=s, prune_delta=delta,
                                       streaming=False)

    def run_stream():
        return build_score_table_fused(data, q=q, s=s, prune_delta=delta)

    # correctness first — the two pruned tables must be bitwise identical
    sp_d = run_dense_prune()
    sp_s, info = build_score_table_fused(data, q=q, s=s, prune_delta=delta,
                                         return_info=True)
    for field in ("kept_idx", "kept_ls", "kept_parents", "keys", "vals"):
        a = np.asarray(getattr(sp_d, field))
        b = np.asarray(getattr(sp_s, field))
        assert np.array_equal(a, b), f"stream != dense+prune on {field}"
    del sp_d, sp_s

    t_dense = timeit(lambda: run_dense_prune().kept_ls)
    t_stream = timeit(lambda: run_stream().kept_ls)
    S = n_parent_sets(n - 1, s)
    return {
        "n": n, "q": q, "s": s, "m": m, "S": S,
        "mode": "stream", "prune_delta": delta,
        "dense_s": t_dense,
        "stream_s": t_stream,
        "speedup": t_dense / t_stream,
        "dense_table_bytes": n * S * 4,
        "peak_assembly_bytes": info["peak_assembly_bytes"],
        "assembly_mem_frac": info["peak_assembly_bytes"] / (n * S * 4),
        "peak_rss_kb": resource.getrusage(resource.RUSAGE_SELF).ru_maxrss,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny size — CI wiring check, seconds")
    ap.add_argument("--stream", action="store_true",
                    help="bench the streaming-pruned assembly vs dense "
                         "build-then-prune instead of dense-vs-fused")
    ap.add_argument("--samples", type=int, default=400)
    args = ap.parse_args(argv)

    if args.stream:
        sizes = STREAM_SMOKE_SIZES if args.smoke else STREAM_SIZES
        m = 100 if args.smoke else args.samples
        rows = [bench_stream(n, q, s, m, STREAM_DELTA)
                for (n, q, s) in sizes]
        emit("BENCH_preprocess", rows)
        last = rows[-1]
        print(f"\nn={last['n']} s={last['s']}: streaming assembly peaks at "
              f"{100 * last['assembly_mem_frac']:.1f}% of the dense table "
              f"bytes ({last['speedup']:.2f}x wall clock vs dense+prune)")
        return rows

    sizes = SMOKE_SIZES if args.smoke else SIZES
    m = 100 if args.smoke else args.samples
    rows = [bench_size(n, q, s, m) for (n, q, s) in sizes]
    emit("BENCH_preprocess", rows)
    if not args.smoke:
        last = rows[-1]
        print(f"\nn={last['n']}: fused preprocessing is "
              f"{last['speedup']:.1f}x the dense loop "
              f"(target >= 3x, max err {last['max_abs_err']:.1e})")
    return rows


if __name__ == "__main__":
    main()
