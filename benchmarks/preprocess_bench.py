"""Dense loop vs fused preprocessing pipeline (ISSUE 2 tentpole).

Preprocessing is the end-to-end bottleneck at n >= 37 now that MCMC
iterations are O(window*S) (PR 1). This harness times full score-table
construction both ways on identical data:

* dense:  core/scores.build_score_table — the oracle host loop (per-node
  batched chunk launches, per-node one-hot rebuilds);
* fused:  preprocess.build_score_table_fused — count each column subset once
  against all n children, LUT-score in the same pass, rank-gather assembly.

and reports the speedup plus the max absolute score deviation (gate: >= 3x
at n = 64 and <= 1e-4 error; the fused path is bitwise-equal on CPU).

  PYTHONPATH=src python benchmarks/preprocess_bench.py [--smoke] [--samples M]

Emits experiments/bench/BENCH_preprocess.json.
"""
from __future__ import annotations

import argparse

import numpy as np

try:
    from .common import emit, timeit
except ImportError:                      # run as a plain script
    import os
    import sys
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from common import emit, timeit

from repro.core.combinatorics import n_parent_sets
from repro.core.scores import build_score_table
from repro.preprocess import build_score_table_fused

# (n, q, s): s shrinks as n grows to keep the dense baseline's wall clock
# tractable on CPU — the fused/dense ratio only grows with S.
SIZES = [(16, 2, 3), (37, 2, 3), (64, 2, 2)]
SMOKE_SIZES = [(16, 2, 2)]


def bench_size(n: int, q: int, s: int, m: int) -> dict:
    rng = np.random.default_rng(n)
    data = rng.integers(0, q, size=(m, n)).astype(np.int32)

    def run_dense():
        return build_score_table(data, q=q, s=s).table

    def run_fused():
        return build_score_table_fused(data, q=q, s=s).table

    # correctness first — never time a wrong result
    err = float(np.abs(np.asarray(run_fused()) - np.asarray(run_dense())).max())
    assert err <= 1e-4, f"fused deviates from oracle by {err}"

    t_dense = timeit(run_dense)
    t_fused = timeit(run_fused)
    return {
        "n": n, "q": q, "s": s, "m": m, "S": n_parent_sets(n - 1, s),
        "dense_s": t_dense,
        "fused_s": t_fused,
        "speedup": t_dense / t_fused,
        "max_abs_err": err,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny size — CI wiring check, seconds")
    ap.add_argument("--samples", type=int, default=400)
    args = ap.parse_args(argv)

    sizes = SMOKE_SIZES if args.smoke else SIZES
    m = 100 if args.smoke else args.samples
    rows = [bench_size(n, q, s, m) for (n, q, s) in sizes]
    emit("BENCH_preprocess", rows)
    if not args.smoke:
        last = rows[-1]
        print(f"\nn={last['n']}: fused preprocessing is "
              f"{last['speedup']:.1f}x the dense loop "
              f"(target >= 3x, max err {last['max_abs_err']:.1e})")
    return rows


if __name__ == "__main__":
    main()
