"""Quickstart: learn a small Bayesian network from synthetic data in ~30 s.

  PYTHONPATH=src python examples/quickstart.py

Covers the paper's full loop: ancestral-sample data from a known ground
truth -> precompute the local-score table (the 'hash table', Eq. 4) ->
order-space MCMC with the max-based order score (Eq. 6) -> recover the best
graph (no postprocessing) -> compare against the ground truth.
"""
import numpy as np

from repro.core import random_cpts, random_dag, roc_point
from repro.data.bn_sampler import ancestral_sample
from repro.launch.bn_learn import LearnConfig, learn_structure


def main():
    rng = np.random.default_rng(0)
    n, q, m = 12, 2, 2000
    truth = random_dag(rng, n, max_parents=3)
    data = ancestral_sample(rng, truth, random_cpts(rng, truth, q), m, q)

    out = learn_structure(data, LearnConfig(q=q, s=3, iters=2000, chains=2))

    fp, tp = roc_point(out["adjacency"], truth)
    print(f"nodes={n}  parent-set table S={out['S']}")
    print(f"best log-score  {out['score']:.2f}")
    print(f"preprocess      {out['preprocess_s']:.2f}s"
          f"   sampling {out['iteration_s']:.2f}s"
          f" ({out['per_iteration_s']*1e3:.2f} ms/iter)")
    print(f"accept rate     {out['accept_rate']:.2f}")
    print(f"TP rate {tp:.3f}   FP rate {fp:.4f}")
    print("\nlearned adjacency (rows=child's parents):")
    print(out["adjacency"])


if __name__ == "__main__":
    main()
