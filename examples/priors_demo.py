"""Pairwise priors (paper §IV): encode confidence about single edges in the
interface matrix R and watch precision/recall move (paper Figs 9/10).

  PYTHONPATH=src python examples/priors_demo.py
"""
import numpy as np

from repro.core import random_cpts, random_dag, roc_point
from repro.core.priors import make_prior_matrix, ppf
from repro.data.bn_sampler import ancestral_sample
from repro.launch.bn_learn import LearnConfig, learn_structure


def main():
    rng = np.random.default_rng(3)
    n, q, m = 16, 2, 800                      # deliberately data-starved
    truth = random_dag(rng, n, max_parents=3)
    data = ancestral_sample(rng, truth, random_cpts(rng, truth, q), m, q)
    cfg = LearnConfig(q=q, s=3, iters=3000, seed=1)

    print("PPF(R): R=0.9 ->", f"{float(ppf(np.float32(0.9))):+.2f}",
          " R=0.5 -> +0.00   R=0.1 ->",
          f"{float(ppf(np.float32(0.1))):+.2f}", "(log10 units, Eq. 10)")

    base = learn_structure(data, cfg)
    fp0, tp0 = roc_point(base["adjacency"], truth)
    print(f"no prior:    TP {tp0:.3f}  FP {fp0:.4f}")

    # user knows 30% of the true edges exist (R=0.85)
    known = [(m_, i_) for (m_, i_) in zip(*np.nonzero(truth))
             if rng.random() < 0.3]
    R = make_prior_matrix(n, known_edges=known, confidence=0.85)
    out = learn_structure(data, cfg, prior_matrix=np.asarray(R))
    fp1, tp1 = roc_point(out["adjacency"], truth)
    print(f"edge priors on {len(known)} known edges: TP {tp1:.3f}  FP {fp1:.4f}")

    # user additionally forbids some non-edges (R=0.15)
    nonedges = [(a, b) for a in range(n) for b in range(n)
                if a != b and truth[a, b] == 0 and rng.random() < 0.1]
    R2 = make_prior_matrix(n, known_edges=known, forbidden_edges=nonedges,
                           confidence=0.85)
    out2 = learn_structure(data, cfg, prior_matrix=np.asarray(R2))
    fp2, tp2 = roc_point(out2["adjacency"], truth)
    print(f"+ forbidden priors on {len(nonedges)} non-edges: "
          f"TP {tp2:.3f}  FP {fp2:.4f}")


if __name__ == "__main__":
    main()
