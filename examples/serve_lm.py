"""Batched serving example: prefill + greedy decode with a KV cache (and
recurrent state for the SSM/hybrid archs — same driver, same API).

  PYTHONPATH=src python examples/serve_lm.py [--arch rwkv6-7b]
"""
import argparse

from repro.launch import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    out = serve.main([
        "--arch", args.arch, "--reduced",
        "--batch", str(args.batch),
        "--prompt-len", "32", "--gen", str(args.gen),
    ])
    print(f"\ngenerated {out['tokens'].shape} tokens in "
          f"{out['seconds']:.2f}s")


if __name__ == "__main__":
    main()
