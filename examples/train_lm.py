"""End-to-end LM training driver: ~100M-param model, a few hundred steps,
with async checkpointing + deterministic resume (kill it mid-run and rerun —
it continues from the last snapshot).

  PYTHONPATH=src python examples/train_lm.py [--steps 300]

This drives the same repro.launch.train used for the full assigned configs on
the production mesh; here it runs a width-reduced yi-34b (llama-family GQA)
on CPU. ~100M params: 12L × d=768 × ff=2048, vocab 32k.
"""
import argparse

from repro.launch import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    out = train.main([
        "--arch", "yi-34b", "--reduced-100m",
        "--steps", str(args.steps),
        "--batch", "4", "--seq", "128",
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100",
        "--log-every", "20",
    ])
    print(f"\nloss {out['first_loss']:.3f} -> {out['last_loss']:.3f} "
          f"over {args.steps} steps")
    assert out["last_loss"] < out["first_loss"], "training did not learn"


if __name__ == "__main__":
    main()
