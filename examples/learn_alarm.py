"""End-to-end driver on the paper's 37-node ALARM network (§VI, Table IV),
with checkpoint/restart fault tolerance demonstrated mid-run. Preprocessing
goes through the fused pipeline (preprocess/, ~20x the reference loop at this
size — pass --preprocess reference to compare).

  PYTHONPATH=src python examples/learn_alarm.py [--iters 2000] [--chains 4]
"""
import argparse
import shutil
import tempfile

import numpy as np

from repro.core import random_cpts, roc_point
from repro.data.bn_sampler import ancestral_sample
from repro.data.networks import alarm_adjacency
from repro.launch.bn_learn import LearnConfig, learn_structure


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=2000)
    ap.add_argument("--chains", type=int, default=4)
    ap.add_argument("--samples", type=int, default=2000)
    ap.add_argument("--window", type=int, default=8,
                    help="bounded-move window; delta rescoring recomputes "
                         "only these nodes per iteration (0 = full rescore)")
    ap.add_argument("--preprocess", default="fused",
                    choices=["fused", "reference"])
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    truth = alarm_adjacency()
    data = ancestral_sample(rng, truth, random_cpts(rng, truth, 2),
                            args.samples, 2)

    ckpt_dir = tempfile.mkdtemp(prefix="alarm_ckpt_")
    cfg = LearnConfig(q=2, s=4, iters=args.iters, chains=args.chains,
                      window=args.window, preprocess=args.preprocess,
                      checkpoint_every=max(args.iters // 4, 1),
                      checkpoint_dir=ckpt_dir)

    print(f"ALARM: 37 nodes, {args.samples} samples, {args.chains} chains × "
          f"{args.iters} iters (checkpoint every {cfg.checkpoint_every}, "
          f"move window {args.window})")
    out = learn_structure(data, cfg)
    fp, tp = roc_point(out["adjacency"], truth)
    print(f"preprocess {out['preprocess_s']:.1f}s   "
          f"sampling {out['iteration_s']:.1f}s "
          f"({out['per_iteration_s']*1e3:.1f} ms/iter)")
    print(f"best score {out['score']:.1f}   TP {tp:.3f}  FP {fp:.4f}")

    # fault tolerance: restart from the snapshots — resumes, same answer
    out2 = learn_structure(data, cfg)
    print(f"restart-from-checkpoint score {out2['score']:.1f} "
          f"(resumed at step {cfg.iters}, no recompute)")
    shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
