# Tier-1 verification targets (mirrored by .github/workflows/ci.yml).
PY ?= python
export PYTHONPATH := src:$(PYTHONPATH)

.PHONY: test bench-smoke bench-delta

test:
	$(PY) -m pytest -q

bench-smoke:
	$(PY) benchmarks/delta_vs_full.py --smoke

bench-delta:
	$(PY) benchmarks/delta_vs_full.py
