# Tier-1 verification targets (mirrored by .github/workflows/ci.yml).
PY ?= python
export PYTHONPATH := src:$(PYTHONPATH)

.PHONY: test bench-smoke bench-delta bench-mcmc bench-mcmc-smoke \
        bench-preprocess bench-preprocess-smoke

test:
	$(PY) -m pytest -q

bench-smoke:
	$(PY) benchmarks/delta_vs_full.py --smoke
	$(PY) benchmarks/preprocess_bench.py --smoke
	$(PY) benchmarks/mcmc_bench.py --smoke

bench-delta:
	$(PY) benchmarks/delta_vs_full.py

bench-mcmc:
	$(PY) benchmarks/mcmc_bench.py

bench-mcmc-smoke:
	$(PY) benchmarks/mcmc_bench.py --smoke

bench-preprocess:
	$(PY) benchmarks/preprocess_bench.py

bench-preprocess-smoke:
	$(PY) benchmarks/preprocess_bench.py --smoke
