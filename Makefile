# Tier-1 verification targets (mirrored by .github/workflows/ci.yml).
PY ?= python
export PYTHONPATH := src:$(PYTHONPATH)

.PHONY: test lint lint-fixtures bench-smoke bench-delta bench-mcmc bench-mcmc-smoke \
        bench-mcmc-sharded bench-mcmc-sharded-smoke \
        bench-preprocess bench-preprocess-smoke \
        bench-preprocess-stream bench-preprocess-stream-smoke \
        bench-telemetry bench-telemetry-smoke telemetry-smoke \
        bench-faults bench-faults-smoke \
        bench-supervisor bench-supervisor-smoke chaos-smoke \
        bench-serve bench-serve-smoke serve-smoke

test:
	$(PY) -m pytest -q

# bnlint static analysis (docs/static-analysis.md): retrace, host-sync,
# pallas-contract, pytree-drift and emit-site rules over the real tree.
# Exits nonzero on any finding not in analysis/baseline.json (every baseline
# entry carries a mandatory reason) or suppressed inline.
lint:
	$(PY) -m repro.analysis src benchmarks --fail-on-findings

# analyzer self-test: the deliberately-broken fixture corpus must (a) fail
# the normal gate and (b) trip every rule family (--expect exits nonzero if
# any listed rule does not fire)
lint-fixtures:
	@! $(PY) -m repro.analysis tests/fixtures/bnlint --no-baseline \
	  --fail-on-findings > /dev/null || \
	  (echo "lint-fixtures: corpus unexpectedly passed the gate" && exit 1)
	$(PY) -m repro.analysis tests/fixtures/bnlint --no-baseline \
	  --expect retrace-eager-switch,retrace-undeclared-static,retrace-loop-varying-static,hostsync-in-hot-path,pallas-spec-mismatch,pallas-interpret-hardcoded,pytree-unregistered-field,telemetry-unknown-kind,bench-unknown-config-key,bench-row-no-config

bench-smoke:
	$(PY) benchmarks/delta_vs_full.py --smoke
	$(PY) benchmarks/preprocess_bench.py --smoke
	$(PY) benchmarks/mcmc_bench.py --smoke

bench-delta:
	$(PY) benchmarks/delta_vs_full.py

bench-mcmc:
	$(PY) benchmarks/mcmc_bench.py

bench-mcmc-smoke:
	$(PY) benchmarks/mcmc_bench.py --smoke

# the sharded pair runs on a simulated 4-device host mesh (the bench forces
# the device count itself); results mirror to repo-root BENCH_mcmc_sharded.json
bench-mcmc-sharded:
	$(PY) benchmarks/mcmc_bench.py --sharded

bench-mcmc-sharded-smoke:
	$(PY) benchmarks/mcmc_bench.py --sharded --smoke

bench-preprocess:
	$(PY) benchmarks/preprocess_bench.py

bench-preprocess-smoke:
	$(PY) benchmarks/preprocess_bench.py --smoke

# streaming-pruned assembly vs dense build-then-prune: wall clock + peak
# assembly bytes + peak RSS; rows merge into BENCH_preprocess.json by config
bench-preprocess-stream:
	$(PY) benchmarks/preprocess_bench.py --stream

bench-preprocess-stream-smoke:
	$(PY) benchmarks/preprocess_bench.py --stream --smoke

# telemetry tap overhead (taps on vs off, same keys; gate <= 5% at n = 64);
# rows merge into BENCH_mcmc.json with mode="telemetry"
bench-telemetry:
	$(PY) benchmarks/telemetry_bench.py

bench-telemetry-smoke:
	$(PY) benchmarks/telemetry_bench.py --smoke

# bit-flip fault-injection study (paper's robustness angle): recovered-score
# gap and structural F1 vs flip rate; rows merge into BENCH_faults.json
bench-faults:
	$(PY) benchmarks/fault_injection.py

bench-faults-smoke:
	$(PY) benchmarks/fault_injection.py --smoke

# run-supervisor overhead vs the bare segment loop (gate <= 5% iters/sec at
# n = 64); rows merge into BENCH_mcmc.json with mode="supervised"
bench-supervisor:
	$(PY) benchmarks/supervisor_bench.py

bench-supervisor-smoke:
	$(PY) benchmarks/supervisor_bench.py --smoke

# chaos harness: injected mid-run crash + corrupted checkpoint leaf on the
# single-device AND sharded engines must auto-resume to a bitwise-identical
# result; poisoned/stalled chains must heal; all traces re-validate
chaos-smoke:
	$(PY) -m repro.launch.chaos

# posterior-service scheduling overhead: K jobs sequential vs interleaved
# through the FleetScheduler (gate >= 90% aggregate iters/sec at n = 32);
# rows merge into BENCH_mcmc.json with mode="serve"
bench-serve:
	$(PY) benchmarks/serve_bench.py

bench-serve-smoke:
	$(PY) benchmarks/serve_bench.py --smoke

# end-to-end posterior-service gate: in-process bn_serve on an ephemeral
# port; two synthetic datasets (one duplicated — must dedup to the same job
# id), polled to convergence, every response validated against the
# bn-service/v1 schema, artifacts asserted bitwise-equal to standalone
# same-seed runs, offline bn_query round-trip, clean shutdown
serve-smoke:
	$(PY) -m repro.launch.serve_smoke

# end-to-end telemetry wiring check: a short --telemetry --stop-on-converge
# run, then schema re-validation of the emitted JSONL trace
telemetry-smoke:
	$(PY) -m repro.launch.bn_learn --network stn --iters 400 --chains 4 \
	  --s 2 --samples 300 --exchange-every 50 --telemetry \
	  --stop-on-converge --trace-every 4 --check-every 100 \
	  --rhat-threshold 1.2 --patience 2 \
	  --trace-dir experiments/runs --run-name ci_smoke
	$(PY) -m repro.telemetry.validate experiments/runs/ci_smoke.jsonl
